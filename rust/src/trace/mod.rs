//! Virtual-timeline span tracing: typed, attributed observability over
//! [`crate::clock::Timeline`] reservations.
//!
//! The timeline knows *when* each resource was busy; this module records
//! *why*. Every compute/transfer charge the engine makes can be tagged
//! with a [`SpanKind`] (what the time bought), the owning session, the
//! MoE layer, and the scheduler tick, and pushed into a bounded ring
//! buffer ([`Tracer`]). Two consumers exist:
//!
//! - [`Tracer::chrome_trace`] exports the ring as Chrome trace-event
//!   JSON (the `{"traceEvents": [...]}` schema): one *pid* per virtual
//!   resource stream (GPU compute, PCIe link), one *tid* per session, so
//!   the file loads directly in Perfetto / `chrome://tracing` and shows
//!   transfers overlapping compute exactly as the discrete-event model
//!   scheduled them.
//! - [`Tracer::kind_totals`] / [`Tracer::breakdown_table`] aggregate
//!   busy seconds per kind for `table2_throughput`-style terminal
//!   reports.
//! - [`analysis`] turns the ring into answers: per-window utilization,
//!   per-request critical paths, aggregate bottleneck attribution, and
//!   counterfactual what-if replays (2× link, infinite expert cache,
//!   speculation off) — the coordinator's `analyze` command and the
//!   load harness's SLO reports are built on it.
//!
//! Tracing is opt-in via `ServingConfig::trace`. A disabled tracer
//! ([`Tracer::disabled`]) never allocates and every `record` call is a
//! branch on a bool — the engine's timing and output are byte-identical
//! with tracing on or off; only observability differs.

pub mod analysis;

use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::clock::{Resource, Span};
use crate::telemetry::Table;
use crate::util::json::Json;

/// What a timeline reservation bought. Compute kinds run on the GPU
/// stream; transfer kinds occupy the PCIe link. Expert transfers are
/// attributed by *cause*: a demand load blocks the decode front, a
/// speculative prefetch rides under the previous layers' compute
/// (paper §3.2), a KV resume re-stages swapped-out state, a prefix seed
/// copies cached prompt KV, and a tier reload re-fetches an expert whose
/// resident copy was dropped by an adaptive re-tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Token embedding + per-step launch overhead (GPU).
    Embed,
    /// Attention block compute for one layer (GPU).
    Attention,
    /// Router/gate compute, including speculative re-gating (GPU).
    Gate,
    /// Expert FFN compute — single, stacked, or mixed kernels (GPU).
    ExpertCompute,
    /// LM head projection (GPU).
    LmHead,
    /// Expert fetched because the current layer needs it *now* (link).
    DemandLoad,
    /// Expert prefetched from a speculative routing guess (link).
    SpecPrefetch,
    /// KV pages swapped to/from host for preemption/resume (link).
    KvResume,
    /// Cached prefix KV copied into a fresh session (link).
    PrefixSeed,
    /// Expert re-fetched after an adaptive re-tier dropped it (link).
    TierReload,
    /// Link time burned by an injected-fault retry: the failed attempt
    /// plus its exponential backoff, charged so recovery cost is
    /// visible on the timeline (link).
    FaultRetry,
}

impl SpanKind {
    /// Every kind, compute first — iteration order for reports and the
    /// CI completeness check.
    pub const ALL: [SpanKind; 11] = [
        SpanKind::Embed,
        SpanKind::Attention,
        SpanKind::Gate,
        SpanKind::ExpertCompute,
        SpanKind::LmHead,
        SpanKind::DemandLoad,
        SpanKind::SpecPrefetch,
        SpanKind::KvResume,
        SpanKind::PrefixSeed,
        SpanKind::TierReload,
        SpanKind::FaultRetry,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Embed => "embed",
            SpanKind::Attention => "attention",
            SpanKind::Gate => "gate",
            SpanKind::ExpertCompute => "expert_compute",
            SpanKind::LmHead => "lm_head",
            SpanKind::DemandLoad => "demand_load",
            SpanKind::SpecPrefetch => "spec_prefetch",
            SpanKind::KvResume => "kv_resume",
            SpanKind::PrefixSeed => "prefix_seed",
            SpanKind::TierReload => "tier_reload",
            SpanKind::FaultRetry => "fault_retry",
        }
    }

    /// Which virtual resource stream this kind occupies.
    pub fn resource(&self) -> Resource {
        match self {
            SpanKind::Embed
            | SpanKind::Attention
            | SpanKind::Gate
            | SpanKind::ExpertCompute
            | SpanKind::LmHead => Resource::Gpu,
            SpanKind::DemandLoad
            | SpanKind::SpecPrefetch
            | SpanKind::KvResume
            | SpanKind::PrefixSeed
            | SpanKind::TierReload
            | SpanKind::FaultRetry => Resource::Link,
        }
    }

    pub fn is_transfer(&self) -> bool {
        self.resource() == Resource::Link
    }
}

/// One attributed timeline reservation. Times are virtual seconds.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpan {
    pub kind: SpanKind,
    pub start_s: f64,
    pub end_s: f64,
    /// Owning session id (0 for engine-internal work with no session,
    /// e.g. teacher-forced harness runs before a session exists).
    pub session: u64,
    /// MoE layer index, when the work belongs to one layer.
    pub layer: Option<usize>,
    /// Scheduler tick (engine-lifetime counter) the span was issued in.
    pub tick: u64,
}

impl TraceSpan {
    pub fn dur_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Bounded in-memory span ring. When full, the oldest spans are dropped
/// (and counted) — the ring always holds the most recent window, which
/// is what a trace viewer wants.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    capacity: usize,
    spans: VecDeque<TraceSpan>,
    dropped: u64,
}

impl Tracer {
    /// The no-op tracer: `record` is a single branch, nothing allocates.
    pub fn disabled() -> Self {
        Tracer { enabled: false, capacity: 0, spans: VecDeque::new(), dropped: 0 }
    }

    pub fn enabled(capacity: usize) -> Self {
        Tracer {
            enabled: true,
            capacity: capacity.max(1),
            spans: VecDeque::new(),
            dropped: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record a reservation the engine just made on the timeline.
    #[inline]
    pub fn record(
        &mut self,
        kind: SpanKind,
        span: Span,
        session: u64,
        layer: Option<usize>,
        tick: u64,
    ) {
        if !self.enabled {
            return;
        }
        // zero-duration reservations (e.g. an empty transfer) carry no
        // information and would only clutter the viewer
        if span.end <= span.start {
            return;
        }
        if self.spans.len() == self.capacity {
            self.spans.pop_front();
            self.dropped += 1;
        }
        self.spans.push_back(TraceSpan {
            kind,
            start_s: span.start,
            end_s: span.end,
            session,
            layer,
            tick,
        });
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans evicted by the ring bound (oldest-first).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn spans(&self) -> impl Iterator<Item = &TraceSpan> {
        self.spans.iter()
    }

    /// Busy virtual seconds per kind, in [`SpanKind::ALL`] order (kinds
    /// with no spans report 0.0).
    pub fn kind_totals(&self) -> Vec<(SpanKind, f64)> {
        let mut acc: BTreeMap<SpanKind, f64> = BTreeMap::new();
        for s in &self.spans {
            *acc.entry(s.kind).or_insert(0.0) += s.dur_s();
        }
        SpanKind::ALL
            .iter()
            .map(|k| (*k, acc.get(k).copied().unwrap_or(0.0)))
            .collect()
    }

    /// `table2_throughput`-style per-kind breakdown: spans, busy
    /// seconds, and share of the stream's total busy time.
    pub fn breakdown_table(&self) -> Table {
        let mut n: BTreeMap<SpanKind, u64> = BTreeMap::new();
        for s in &self.spans {
            *n.entry(s.kind).or_insert(0) += 1;
        }
        let totals = self.kind_totals();
        let gpu_total: f64 =
            totals.iter().filter(|(k, _)| !k.is_transfer()).map(|(_, v)| v).sum();
        let link_total: f64 =
            totals.iter().filter(|(k, _)| k.is_transfer()).map(|(_, v)| v).sum();
        let mut t = Table::new(&["kind", "stream", "spans", "busy_s", "share"]);
        for (kind, busy) in totals {
            let (stream, stream_total) = if kind.is_transfer() {
                ("link", link_total)
            } else {
                ("gpu", gpu_total)
            };
            let share = if stream_total > 0.0 { busy / stream_total } else { 0.0 };
            t.row(vec![
                kind.label().to_string(),
                stream.to_string(),
                n.get(&kind).copied().unwrap_or(0).to_string(),
                format!("{busy:.6}"),
                format!("{:.1}%", share * 100.0),
            ]);
        }
        t
    }

    /// Export the ring as Chrome trace-event JSON (`{"traceEvents":
    /// [...]}`), loadable in Perfetto or `chrome://tracing`.
    ///
    /// Layout: pid 1 is the virtual GPU compute stream, pid 2 the
    /// virtual PCIe link; tid is the owning session, so each session's
    /// work reads as one horizontal track per resource. Events are
    /// `ph:"X"` complete events with `ts`/`dur` in microseconds of
    /// virtual time; `args` carries the layer and tick.
    pub fn chrome_trace(&self) -> Json {
        self.chrome_trace_with_counters(&[])
    }

    /// [`Self::chrome_trace`] plus caller-supplied `ph:"C"` counter
    /// events (e.g. the expert flight recorder's residency / hit-rate
    /// tracks) appended to the same `traceEvents` array, so gauges
    /// render as stacked counter tracks under the span streams.
    pub fn chrome_trace_with_counters(&self, counters: &[Json]) -> Json {
        const PID_GPU: usize = 1;
        const PID_LINK: usize = 2;
        let mut events: Vec<Json> = vec![
            Json::obj(vec![
                ("ph", "M".into()),
                ("pid", PID_GPU.into()),
                ("name", "process_name".into()),
                ("args", Json::obj(vec![("name", "GPU compute (virtual)".into())])),
            ]),
            Json::obj(vec![
                ("ph", "M".into()),
                ("pid", PID_LINK.into()),
                ("name", "process_name".into()),
                ("args", Json::obj(vec![("name", "PCIe link (virtual)".into())])),
            ]),
        ];
        for s in &self.spans {
            let pid = if s.kind.is_transfer() { PID_LINK } else { PID_GPU };
            let mut args = vec![("tick", Json::from(s.tick as i64))];
            if let Some(layer) = s.layer {
                args.push(("layer", layer.into()));
            }
            events.push(Json::obj(vec![
                ("ph", "X".into()),
                ("name", s.kind.label().into()),
                ("cat", if s.kind.is_transfer() { "transfer" } else { "compute" }.into()),
                ("pid", pid.into()),
                ("tid", Json::from(s.session as i64)),
                ("ts", (s.start_s * 1e6).into()),
                ("dur", (s.dur_s() * 1e6).into()),
                ("args", Json::obj(args)),
            ]));
        }
        events.extend(counters.iter().cloned());
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", "ms".into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(start: f64, end: f64) -> Span {
        Span { start, end }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        t.record(SpanKind::Attention, span(0.0, 1.0), 1, Some(0), 0);
        assert!(t.is_empty());
        assert!(!t.is_enabled());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_drops_oldest_when_full() {
        let mut t = Tracer::enabled(2);
        t.record(SpanKind::Embed, span(0.0, 1.0), 1, None, 0);
        t.record(SpanKind::Gate, span(1.0, 2.0), 1, Some(0), 0);
        t.record(SpanKind::LmHead, span(2.0, 3.0), 1, None, 0);
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 1);
        let kinds: Vec<SpanKind> = t.spans().map(|s| s.kind).collect();
        assert_eq!(kinds, vec![SpanKind::Gate, SpanKind::LmHead]);
    }

    #[test]
    fn zero_duration_spans_are_skipped() {
        let mut t = Tracer::enabled(8);
        t.record(SpanKind::DemandLoad, span(1.0, 1.0), 1, Some(0), 0);
        assert!(t.is_empty());
    }

    #[test]
    fn kind_totals_cover_all_kinds() {
        let mut t = Tracer::enabled(8);
        t.record(SpanKind::Attention, span(0.0, 2.0), 1, Some(0), 0);
        t.record(SpanKind::Attention, span(2.0, 3.0), 1, Some(1), 0);
        t.record(SpanKind::DemandLoad, span(0.0, 4.0), 1, Some(0), 0);
        let totals = t.kind_totals();
        assert_eq!(totals.len(), SpanKind::ALL.len());
        let get = |k: SpanKind| totals.iter().find(|(x, _)| *x == k).unwrap().1;
        assert!((get(SpanKind::Attention) - 3.0).abs() < 1e-12);
        assert!((get(SpanKind::DemandLoad) - 4.0).abs() < 1e-12);
        assert_eq!(get(SpanKind::SpecPrefetch), 0.0);
    }

    #[test]
    fn chrome_trace_roundtrips_and_separates_streams() {
        let mut t = Tracer::enabled(8);
        t.record(SpanKind::ExpertCompute, span(0.0, 1.5), 7, Some(3), 2);
        t.record(SpanKind::SpecPrefetch, span(0.5, 1.0), 7, Some(4), 2);
        let text = t.chrome_trace().to_string();
        let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata events + 2 spans
        assert_eq!(events.len(), 4);
        let compute = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("expert_compute"))
            .unwrap();
        assert_eq!(compute.get("pid").unwrap().as_i64(), Some(1));
        assert_eq!(compute.get("tid").unwrap().as_i64(), Some(7));
        assert_eq!(compute.get("dur").unwrap().as_f64(), Some(1.5e6));
        let prefetch = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("spec_prefetch"))
            .unwrap();
        assert_eq!(prefetch.get("pid").unwrap().as_i64(), Some(2));
        assert_eq!(
            prefetch.get("args").unwrap().get("layer").unwrap().as_usize(),
            Some(4)
        );
    }

    #[test]
    fn counter_events_append_after_spans() {
        let mut t = Tracer::enabled(8);
        t.record(SpanKind::ExpertCompute, span(0.0, 1.0), 1, Some(0), 0);
        let counter = Json::obj(vec![
            ("ph", "C".into()),
            ("pid", 2usize.into()),
            ("name", "expert_residency".into()),
            ("ts", 0.0.into()),
            ("args", Json::obj(vec![("resident", 3usize.into())])),
        ]);
        let out = t.chrome_trace_with_counters(&[counter]);
        let events = out.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 1 span + 1 counter
        assert_eq!(events.len(), 4);
        let last = events.last().unwrap();
        assert_eq!(last.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(
            last.get("args").unwrap().get("resident").unwrap().as_usize(),
            Some(3)
        );
        // plain chrome_trace is unchanged: metadata + span only
        let plain = t.chrome_trace();
        assert_eq!(plain.get("traceEvents").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn breakdown_table_renders_every_kind() {
        let mut t = Tracer::enabled(8);
        t.record(SpanKind::Attention, span(0.0, 1.0), 1, Some(0), 0);
        let r = t.breakdown_table().render();
        for kind in SpanKind::ALL {
            assert!(r.contains(kind.label()), "missing {}", kind.label());
        }
    }

    #[test]
    fn resources_match_kind_class() {
        for kind in SpanKind::ALL {
            match kind {
                SpanKind::DemandLoad
                | SpanKind::SpecPrefetch
                | SpanKind::KvResume
                | SpanKind::PrefixSeed
                | SpanKind::TierReload
                | SpanKind::FaultRetry => assert!(kind.is_transfer()),
                _ => assert!(!kind.is_transfer()),
            }
        }
    }
}
