//! Post-hoc analysis over the span ring: where did the time go, and
//! what would fixing it buy?
//!
//! Four questions, all answered from the recorded [`TraceSpan`]s alone
//! (the ring is the single source of truth — nothing here re-runs the
//! engine):
//!
//! - **Utilization**: per-window GPU / link busy fractions across the
//!   trace horizon ([`utilization_windows`]) — where the streams sat
//!   idle.
//! - **Critical path**: for each session, the chain of spans its decode
//!   front actually advanced through ([`critical_paths`]) — compute,
//!   blocking transfers, and the scheduler gaps between them. The chain
//!   sum never exceeds the session's span window (asserted by property
//!   test and against real engine runs in `tests/trace_spans.rs`).
//! - **Attribution**: aggregate fractions of session wall time spent in
//!   compute vs. blocked on demand loads vs. KV/prefix staging vs.
//!   waiting for a turn ([`attribution`]) — the fractions sum to 1.
//! - **What-if**: counterfactual replays of the recorded spans through
//!   a [`CostModel`]-aware discrete-event rebuild ([`replay`]): double
//!   the link bandwidth (only the bytes term of a transfer shrinks —
//!   latency is latency), make the expert cache infinite (expert
//!   traffic vanishes), or turn speculation off (prefetches become
//!   demand loads). Each scenario reports a projected makespan and the
//!   speedup against the *baseline replay* of the same spans, so model
//!   error divides out of the ratio.
//!
//! The coordinator surfaces all of it through the `analyze` TCP command
//! ([`analyze_response`]); the load harness embeds the same report in
//! its per-profile SLO rows (`rust/src/load/`).

use std::collections::BTreeMap;

use crate::clock::{Resource, Span, Timeline};
use crate::engine::cost::CostModel;
use crate::util::json::Json;

use super::{SpanKind, TraceSpan, Tracer};

/// GPU / link busy fractions over one slice of the trace horizon.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilWindow {
    pub start_s: f64,
    pub end_s: f64,
    /// Fraction of the window the GPU stream was reserved (≤ 1: per-
    /// resource reservations never overlap).
    pub gpu_util: f64,
    pub link_util: f64,
}

/// Slice the trace horizon into `windows` equal slices and sum each
/// resource's span overlap into per-window busy fractions. Empty input
/// (or a zero-length horizon) yields no windows.
pub fn utilization_windows(spans: &[TraceSpan], windows: usize) -> Vec<UtilWindow> {
    if spans.is_empty() || windows == 0 {
        return Vec::new();
    }
    let lo = spans.iter().map(|s| s.start_s).fold(f64::INFINITY, f64::min);
    let hi = spans.iter().map(|s| s.end_s).fold(f64::NEG_INFINITY, f64::max);
    if !(hi > lo) {
        return Vec::new();
    }
    let w = (hi - lo) / windows as f64;
    let mut out: Vec<UtilWindow> = (0..windows)
        .map(|i| UtilWindow {
            start_s: lo + i as f64 * w,
            end_s: lo + (i + 1) as f64 * w,
            gpu_util: 0.0,
            link_util: 0.0,
        })
        .collect();
    for s in spans {
        let span = Span { start: s.start_s, end: s.end_s };
        for win in out.iter_mut() {
            let ov = span.overlap(win.start_s, win.end_s);
            if ov <= 0.0 {
                continue;
            }
            match s.kind.resource() {
                Resource::Gpu => win.gpu_util += ov,
                Resource::Link => win.link_util += ov,
            }
        }
    }
    for win in out.iter_mut() {
        win.gpu_util = (win.gpu_util / w).min(1.0);
        win.link_util = (win.link_util / w).min(1.0);
    }
    out
}

/// One session's critical path: the span chain its decode front actually
/// advanced through, split by what each segment was doing. The exact
/// decomposition is `window_s = compute_s + demand_blocked_s +
/// kv_blocked_s + sched_wait_s` — overlapped span time is clipped to the
/// front, so `path_s` (the first three) can never exceed `window_s`, and
/// at width 1 it equals the request's virtual wall time (the same
/// identity `tests/trace_spans.rs` asserts for the breakdown).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestPath {
    pub session: u64,
    /// Spans that contributed to the chain (fully-overlapped spans drop).
    pub chain: usize,
    /// Front-advancing GPU compute seconds.
    pub compute_s: f64,
    /// Seconds the front sat blocked on expert traffic (demand loads,
    /// tier reloads, and fault-retry recovery).
    pub demand_blocked_s: f64,
    /// Seconds the front sat blocked on KV staging (preempt/resume swaps
    /// and prefix-cache seeds).
    pub kv_blocked_s: f64,
    /// Gaps inside the session's window where nothing of its own ran —
    /// with concurrent sessions, the time it waited for a scheduling
    /// turn on the shared streams.
    pub sched_wait_s: f64,
    /// First span start → last span end (speculative prefetches
    /// excluded: nothing ever waits on them).
    pub window_s: f64,
    /// `compute_s + demand_blocked_s + kv_blocked_s` — the attributed
    /// chain itself, ≤ `window_s` by construction.
    pub path_s: f64,
}

/// Walk each session's spans in start order and attribute every second
/// its front advanced. Speculative prefetches are excluded up front:
/// they ride under compute by design, so only the *demand* tail of
/// expert traffic can appear on a critical path.
pub fn critical_paths(spans: &[TraceSpan]) -> Vec<RequestPath> {
    let mut by_session: BTreeMap<u64, Vec<&TraceSpan>> = BTreeMap::new();
    for s in spans {
        if s.kind == SpanKind::SpecPrefetch {
            continue;
        }
        by_session.entry(s.session).or_default().push(s);
    }
    let mut out = Vec::with_capacity(by_session.len());
    for (session, mut list) in by_session {
        list.sort_by(|a, b| {
            a.start_s.total_cmp(&b.start_s).then(a.end_s.total_cmp(&b.end_s))
        });
        let first = list[0].start_s;
        let mut front = first;
        let mut last_end = first;
        let (mut compute, mut demand, mut kv, mut chain) = (0.0, 0.0, 0.0, 0usize);
        for s in list {
            last_end = last_end.max(s.end_s);
            // only the part past the front advanced it; spans the front
            // already passed (hidden under an earlier blocking wait)
            // contribute nothing
            let c = s.end_s - front.max(s.start_s);
            if c <= 0.0 {
                continue;
            }
            chain += 1;
            match s.kind {
                SpanKind::DemandLoad | SpanKind::TierReload | SpanKind::FaultRetry => {
                    demand += c
                }
                SpanKind::KvResume | SpanKind::PrefixSeed => kv += c,
                _ => compute += c,
            }
            front = s.end_s;
        }
        let window_s = last_end - first;
        let path_s = compute + demand + kv;
        out.push(RequestPath {
            session,
            chain,
            compute_s: compute,
            demand_blocked_s: demand,
            kv_blocked_s: kv,
            sched_wait_s: (window_s - path_s).max(0.0),
            window_s,
            path_s,
        });
    }
    out
}

/// Aggregate bottleneck attribution: what fraction of total session wall
/// time (Σ window) went to compute, demand-loaded expert traffic, KV
/// staging, and waiting for a scheduling turn. The four fractions sum to
/// exactly 1 whenever any time was recorded (all zeros otherwise).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Attribution {
    pub compute_frac: f64,
    pub demand_load_frac: f64,
    pub kv_resume_frac: f64,
    pub queue_frac: f64,
}

impl Attribution {
    pub fn sum(&self) -> f64 {
        self.compute_frac + self.demand_load_frac + self.kv_resume_frac + self.queue_frac
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("compute", self.compute_frac.into()),
            ("demand_load", self.demand_load_frac.into()),
            ("kv_resume", self.kv_resume_frac.into()),
            ("queue", self.queue_frac.into()),
        ])
    }
}

pub fn attribution(paths: &[RequestPath]) -> Attribution {
    let total: f64 = paths.iter().map(|p| p.window_s).sum();
    if total <= 0.0 {
        return Attribution::default();
    }
    Attribution {
        compute_frac: paths.iter().map(|p| p.compute_s).sum::<f64>() / total,
        demand_load_frac: paths.iter().map(|p| p.demand_blocked_s).sum::<f64>() / total,
        kv_resume_frac: paths.iter().map(|p| p.kv_blocked_s).sum::<f64>() / total,
        queue_frac: paths.iter().map(|p| p.sched_wait_s).sum::<f64>() / total,
    }
}

/// Counterfactual scenarios for [`replay`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WhatIf {
    /// The recorded spans rebuilt as-is — the denominator every scenario
    /// is compared against, so cost-model error divides out.
    Baseline,
    /// Link bandwidth doubled: each transfer's bytes term halves, its
    /// fixed DMA/driver latency does not ([`CostModel::rescale_transfer_s`]).
    DoubleLink,
    /// Every expert always resident: demand loads, tier reloads and
    /// speculative prefetches vanish from the link entirely (KV and
    /// prefix traffic stays — it is not expert weight traffic).
    InfiniteExpertCache,
    /// Speculative prefetching disabled: every prefetched expert is
    /// instead fetched on demand, blocking its session's front.
    NoSpeculation,
}

impl WhatIf {
    /// The counterfactuals (everything but the baseline denominator).
    pub const SCENARIOS: [WhatIf; 3] =
        [WhatIf::DoubleLink, WhatIf::InfiniteExpertCache, WhatIf::NoSpeculation];

    pub fn label(&self) -> &'static str {
        match self {
            WhatIf::Baseline => "baseline",
            WhatIf::DoubleLink => "link_2x",
            WhatIf::InfiniteExpertCache => "infinite_expert_cache",
            WhatIf::NoSpeculation => "speculation_off",
        }
    }
}

/// Rebuild the recorded spans as a fresh discrete-event schedule under a
/// scenario and return the projected makespan (latest session front).
///
/// The rebuild replays spans in recorded start order onto a fresh
/// [`Timeline`] with one front per session: GPU spans start at
/// max(gpu-free, front) and advance their session's front; blocking link
/// spans (demand loads, tier reloads, KV swaps, prefix seeds) start at
/// max(link-free, front) and advance it; speculative prefetches are
/// issued at link-free and advance nothing — exactly the engine's own
/// scheduling rules, which is why the baseline replay reconstructs the
/// recorded schedule and the ratio to it isolates the scenario's effect.
pub fn replay(spans: &[TraceSpan], cost: &CostModel, scenario: WhatIf) -> f64 {
    let mut order: Vec<&TraceSpan> = spans.iter().collect();
    order.sort_by(|a, b| {
        a.start_s.total_cmp(&b.start_s).then(a.end_s.total_cmp(&b.end_s))
    });
    let mut tl = Timeline::new();
    let mut fronts: BTreeMap<u64, f64> = BTreeMap::new();
    for s in order {
        let front = fronts.entry(s.session).or_insert(0.0);
        match s.kind.resource() {
            Resource::Gpu => {
                let sp = tl.reserve(Resource::Gpu, s.dur_s(), *front);
                *front = sp.end;
            }
            Resource::Link => {
                if scenario == WhatIf::InfiniteExpertCache
                    && matches!(
                        s.kind,
                        SpanKind::DemandLoad
                            | SpanKind::TierReload
                            | SpanKind::SpecPrefetch
                            | SpanKind::FaultRetry
                    )
                {
                    continue;
                }
                let dur = if scenario == WhatIf::DoubleLink {
                    cost.rescale_transfer_s(s.dur_s(), 2.0)
                } else {
                    s.dur_s()
                };
                let blocking = s.kind != SpanKind::SpecPrefetch
                    || scenario == WhatIf::NoSpeculation;
                let not_before = if blocking { *front } else { 0.0 };
                let sp = tl.reserve(Resource::Link, dur, not_before);
                if blocking {
                    *front = sp.end;
                }
            }
        }
    }
    fronts.values().fold(0.0, |a, &b| a.max(b))
}

/// One scenario's projection against the baseline replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WhatIfRow {
    pub scenario: WhatIf,
    pub baseline_s: f64,
    pub projected_s: f64,
    /// `baseline_s / projected_s` — > 1 means the scenario helps.
    pub speedup: f64,
}

impl WhatIfRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", self.scenario.label().into()),
            ("baseline_s", self.baseline_s.into()),
            ("projected_s", self.projected_s.into()),
            ("speedup", self.speedup.into()),
        ])
    }
}

/// Replay every counterfactual in [`WhatIf::SCENARIOS`].
pub fn whatif_rows(spans: &[TraceSpan], cost: &CostModel) -> Vec<WhatIfRow> {
    let baseline_s = replay(spans, cost, WhatIf::Baseline);
    WhatIf::SCENARIOS
        .iter()
        .map(|&scenario| {
            let projected_s = replay(spans, cost, scenario);
            WhatIfRow {
                scenario,
                baseline_s,
                projected_s,
                speedup: if projected_s > 0.0 { baseline_s / projected_s } else { 1.0 },
            }
        })
        .collect()
}

/// Number of utilization windows the canned reports use.
pub const DEFAULT_UTIL_WINDOWS: usize = 12;

/// The full analysis as one JSON object: utilization windows, per-request
/// critical paths, aggregate attribution, and what-if projections.
pub fn report(spans: &[TraceSpan], cost: &CostModel, windows: usize) -> Json {
    let paths = critical_paths(spans);
    let attr = attribution(&paths);
    Json::obj(vec![
        (
            "utilization",
            Json::arr(utilization_windows(spans, windows).iter().map(|w| {
                Json::obj(vec![
                    ("start_s", w.start_s.into()),
                    ("end_s", w.end_s.into()),
                    ("gpu_util", w.gpu_util.into()),
                    ("link_util", w.link_util.into()),
                ])
            })),
        ),
        (
            "requests",
            Json::arr(paths.iter().map(|p| {
                Json::obj(vec![
                    ("session", (p.session as usize).into()),
                    ("chain", p.chain.into()),
                    ("compute_s", p.compute_s.into()),
                    ("demand_blocked_s", p.demand_blocked_s.into()),
                    ("kv_blocked_s", p.kv_blocked_s.into()),
                    ("sched_wait_s", p.sched_wait_s.into()),
                    ("window_s", p.window_s.into()),
                    ("path_s", p.path_s.into()),
                ])
            })),
        ),
        ("attribution", attr.to_json()),
        ("whatif", Json::arr(whatif_rows(spans, cost).iter().map(WhatIfRow::to_json))),
    ])
}

/// The `analyze` TCP command's response. With tracing off there is
/// nothing to analyze and the response says so explicitly instead of
/// returning an empty report.
pub fn analyze_response(tracer: &Tracer, cost: &CostModel) -> Json {
    if !tracer.is_enabled() {
        return Json::obj(vec![
            ("type", "analyze".into()),
            ("enabled", false.into()),
            ("error", "tracing disabled".into()),
        ]);
    }
    let spans: Vec<TraceSpan> = tracer.spans().copied().collect();
    let mut obj = BTreeMap::new();
    obj.insert("type".to_string(), Json::from("analyze"));
    obj.insert("enabled".to_string(), Json::from(true));
    obj.insert("spans".to_string(), Json::from(tracer.len()));
    obj.insert("spans_dropped".to_string(), Json::from(tracer.dropped() as usize));
    if let Json::Obj(fields) = report(&spans, cost, DEFAULT_UTIL_WINDOWS) {
        obj.extend(fields);
    }
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareProfile, ModelConfig, QuantScheme, SimScale};
    use crate::util::prop::{check, ensure};

    fn ts(kind: SpanKind, start_s: f64, end_s: f64, session: u64) -> TraceSpan {
        TraceSpan { kind, start_s, end_s, session, layer: None, tick: 0 }
    }

    fn cost() -> CostModel {
        CostModel::new(
            HardwareProfile::rtx3060(),
            &ModelConfig::tiny(),
            SimScale::Tiny,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
        )
    }

    #[test]
    fn critical_path_attributes_blocking_time_and_skips_spec() {
        let spans = vec![
            ts(SpanKind::Attention, 0.0, 1.0, 1),
            ts(SpanKind::DemandLoad, 1.0, 3.0, 1),
            ts(SpanKind::ExpertCompute, 3.0, 4.0, 1),
            // hidden prefetch: never on the path, never in the window
            ts(SpanKind::SpecPrefetch, 0.0, 10.0, 1),
        ];
        let paths = critical_paths(&spans);
        assert_eq!(paths.len(), 1);
        let p = &paths[0];
        assert_eq!(p.session, 1);
        assert_eq!(p.chain, 3);
        assert!((p.compute_s - 2.0).abs() < 1e-12);
        assert!((p.demand_blocked_s - 2.0).abs() < 1e-12);
        assert_eq!(p.kv_blocked_s, 0.0);
        assert!((p.window_s - 4.0).abs() < 1e-12);
        assert!((p.path_s - 4.0).abs() < 1e-12);
        assert_eq!(p.sched_wait_s, 0.0);
    }

    #[test]
    fn critical_path_clips_overlap_and_counts_gaps_as_sched_wait() {
        // the demand load overlaps the compute span: only its tail past
        // the front counts; the [4,6] gap before the last span is time
        // the session owned nothing — scheduler wait
        let spans = vec![
            ts(SpanKind::Attention, 0.0, 2.0, 7),
            ts(SpanKind::DemandLoad, 1.0, 3.0, 7),
            ts(SpanKind::ExpertCompute, 6.0, 7.0, 7),
        ];
        let p = &critical_paths(&spans)[0];
        assert!((p.compute_s - 3.0).abs() < 1e-12);
        assert!((p.demand_blocked_s - 1.0).abs() < 1e-12);
        assert!((p.window_s - 7.0).abs() < 1e-12);
        assert!((p.sched_wait_s - 3.0).abs() < 1e-12);
        assert!(p.path_s <= p.window_s);
    }

    #[test]
    fn fully_hidden_span_drops_from_the_chain() {
        let spans = vec![
            ts(SpanKind::KvResume, 0.0, 5.0, 2),
            // entirely under the resume wait: contributes nothing
            ts(SpanKind::Attention, 1.0, 2.0, 2),
        ];
        let p = &critical_paths(&spans)[0];
        assert_eq!(p.chain, 1);
        assert!((p.kv_blocked_s - 5.0).abs() < 1e-12);
        assert_eq!(p.compute_s, 0.0);
    }

    #[test]
    fn attribution_fractions_sum_to_one_and_split_by_cause() {
        let spans = vec![
            ts(SpanKind::Attention, 0.0, 1.0, 1),
            ts(SpanKind::DemandLoad, 1.0, 2.0, 1),
            ts(SpanKind::KvResume, 2.0, 3.0, 1),
            ts(SpanKind::LmHead, 5.0, 6.0, 1), // 2s sched gap
        ];
        let a = attribution(&critical_paths(&spans));
        assert!((a.sum() - 1.0).abs() < 1e-12);
        assert!((a.compute_frac - 2.0 / 6.0).abs() < 1e-12);
        assert!((a.demand_load_frac - 1.0 / 6.0).abs() < 1e-12);
        assert!((a.kv_resume_frac - 1.0 / 6.0).abs() < 1e-12);
        assert!((a.queue_frac - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn attribution_of_nothing_is_all_zero() {
        let a = attribution(&critical_paths(&[]));
        assert_eq!(a.sum(), 0.0);
    }

    #[test]
    fn utilization_windows_measure_overlap_per_resource() {
        let spans = vec![
            ts(SpanKind::Attention, 0.0, 1.0, 1),
            ts(SpanKind::DemandLoad, 0.0, 2.0, 1),
        ];
        let w = utilization_windows(&spans, 2);
        assert_eq!(w.len(), 2);
        assert!((w[0].gpu_util - 1.0).abs() < 1e-12);
        assert!((w[0].link_util - 1.0).abs() < 1e-12);
        assert_eq!(w[1].gpu_util, 0.0);
        assert!((w[1].link_util - 1.0).abs() < 1e-12);
        assert!(utilization_windows(&[], 4).is_empty());
    }

    #[test]
    fn baseline_replay_reconstructs_a_serial_schedule() {
        let spans = vec![
            ts(SpanKind::Attention, 0.0, 1.0, 1),
            ts(SpanKind::DemandLoad, 1.0, 3.0, 1),
            ts(SpanKind::ExpertCompute, 3.0, 4.0, 1),
        ];
        let cm = cost();
        assert!((replay(&spans, &cm, WhatIf::Baseline) - 4.0).abs() < 1e-12);
        // all expert traffic gone: the two compute spans run back to back
        assert!(
            (replay(&spans, &cm, WhatIf::InfiniteExpertCache) - 2.0).abs() < 1e-12
        );
        // 2× link: the demand load's bytes term halves, latency stays
        let lat = cm.profile.h2d_latency_s;
        let want = 2.0 + lat + (2.0 - lat) / 2.0;
        assert!((replay(&spans, &cm, WhatIf::DoubleLink) - want).abs() < 1e-12);
    }

    #[test]
    fn no_speculation_turns_prefetches_into_blocking_loads() {
        let spans = vec![
            ts(SpanKind::SpecPrefetch, 0.0, 2.0, 1),
            ts(SpanKind::Attention, 0.0, 3.0, 1),
            ts(SpanKind::ExpertCompute, 3.0, 4.0, 1),
        ];
        let cm = cost();
        // hidden under compute: the prefetch costs nothing
        assert!((replay(&spans, &cm, WhatIf::Baseline) - 4.0).abs() < 1e-12);
        // forced on demand it serializes ahead of the compute chain
        assert!((replay(&spans, &cm, WhatIf::NoSpeculation) - 6.0).abs() < 1e-12);
        let rows = whatif_rows(&spans, &cm);
        let spec_off =
            rows.iter().find(|r| r.scenario == WhatIf::NoSpeculation).unwrap();
        assert!(spec_off.speedup < 1.0, "losing speculation must not speed up");
    }

    #[test]
    fn analyze_response_degrades_explicitly_without_tracing() {
        let j = analyze_response(&Tracer::disabled(), &cost());
        assert_eq!(j.get("type").unwrap().as_str(), Some("analyze"));
        assert_eq!(j.get("enabled").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error").unwrap().as_str(), Some("tracing disabled"));
        assert!(j.get("attribution").is_none());
    }

    #[test]
    fn analyze_response_carries_the_full_report() {
        let mut tr = Tracer::enabled(64);
        tr.record(
            SpanKind::Attention,
            crate::clock::Span { start: 0.0, end: 1.0 },
            1,
            Some(0),
            1,
        );
        tr.record(
            SpanKind::DemandLoad,
            crate::clock::Span { start: 1.0, end: 2.0 },
            1,
            Some(0),
            1,
        );
        let j = analyze_response(&tr, &cost());
        assert_eq!(j.get("enabled").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("spans").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("spans_dropped").unwrap().as_usize(), Some(0));
        assert!(j.get("attribution").unwrap().get("compute").is_some());
        assert_eq!(j.get("requests").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("whatif").unwrap().as_arr().unwrap().len(), 3);
        // the envelope must survive the line protocol round trip
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("type").unwrap().as_str(), Some("analyze"));
    }

    /// Randomized span soups: the structural identities must hold for
    /// ARBITRARY inputs, not just engine-shaped ones — path ≤ window,
    /// fractions sum to 1, and the what-if replays move in the only
    /// direction their scenario allows.
    #[test]
    fn prop_path_attribution_and_whatif_identities() {
        let cm = cost();
        check(
            "analysis-identities",
            200,
            |r| {
                let n = r.below(40);
                (0..n)
                    .map(|_| {
                        let start = r.f64() * 10.0;
                        let dur = 1e-6 + r.f64() * 2.0;
                        ts(
                            SpanKind::ALL[r.below(SpanKind::ALL.len())],
                            start,
                            start + dur,
                            r.below(3) as u64,
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |spans| {
                let paths = critical_paths(spans);
                for p in &paths {
                    ensure(p.path_s <= p.window_s + 1e-9, "path exceeds window")?;
                    ensure(p.sched_wait_s >= 0.0, "negative sched wait")?;
                    ensure(
                        (p.compute_s + p.demand_blocked_s + p.kv_blocked_s - p.path_s)
                            .abs()
                            < 1e-9,
                        "path components do not sum",
                    )?;
                }
                let a = attribution(&paths);
                let total: f64 = paths.iter().map(|p| p.window_s).sum();
                if total > 0.0 {
                    ensure((a.sum() - 1.0).abs() < 1e-9, "fractions do not sum to 1")?;
                } else {
                    ensure(a.sum() == 0.0, "empty attribution must be zero")?;
                }
                let base = replay(spans, &cm, WhatIf::Baseline);
                ensure(
                    replay(spans, &cm, WhatIf::DoubleLink) <= base + 1e-9,
                    "a faster link slowed the replay down",
                )?;
                ensure(
                    replay(spans, &cm, WhatIf::InfiniteExpertCache) <= base + 1e-9,
                    "an infinite cache slowed the replay down",
                )?;
                ensure(
                    replay(spans, &cm, WhatIf::NoSpeculation) >= base - 1e-9,
                    "losing speculation sped the replay up",
                )?;
                Ok(())
            },
        );
    }
}
