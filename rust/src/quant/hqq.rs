//! Half-Quadratic Quantization (HQQ, Badri & Shaji 2023) — data-free group
//! quantizer, re-implemented from the published algorithm.
//!
//! Affine group quantization `w ≈ (q - z) * s` with groups along the input
//! dimension (matching the Pallas kernel layout). The starting point is
//! min/max affine quantization — bit-identical to the python oracle
//! `kernels/ref.py::quantize_group` — followed by HQQ's half-quadratic
//! refinement of the zero point: alternating between a generalized
//! soft-threshold (the prox of the ‖·‖_p sparsity prior, p < 1, on the
//! reconstruction error) and a closed-form zero-point update.
//!
//! `refine_iters = 0` reproduces the plain min/max quantizer exactly.

use crate::error::{Error, Result};
use crate::quant::bitpack;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct HqqConfig {
    pub bits: u8,
    pub group_size: usize,
    /// Half-quadratic refinement iterations (HQQ default ~20).
    pub refine_iters: usize,
    /// lp norm of the error prior (HQQ uses p < 1 for outlier robustness).
    pub lp_norm: f64,
    /// Initial beta (penalty strength) and its per-iteration growth.
    pub beta: f64,
    pub kappa: f64,
}

impl HqqConfig {
    pub fn new(bits: u8, group_size: usize) -> Self {
        HqqConfig {
            bits,
            group_size,
            refine_iters: 20,
            lp_norm: 0.7,
            beta: 1e1,
            kappa: 1.01,
        }
    }

    pub fn plain(bits: u8, group_size: usize) -> Self {
        HqqConfig { refine_iters: 0, ..Self::new(bits, group_size) }
    }
}

/// A quantized `[n_in, n_out]` weight matrix: bit-packed codes plus f32
/// scale/zero per (group, column). Groups tile the input dimension.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub packed: Vec<u8>,
    pub scale: Vec<f32>, // [n_groups * n_out]
    pub zero: Vec<f32>,  // [n_groups * n_out]
    pub n_in: usize,
    pub n_out: usize,
    pub bits: u8,
    pub group_size: usize,
}

impl QuantizedMatrix {
    pub fn n_groups(&self) -> usize {
        self.n_in / self.group_size
    }

    /// Packed + metadata byte count actually held in host memory.
    pub fn stored_bytes(&self) -> u64 {
        (self.packed.len() + self.scale.len() * 4 + self.zero.len() * 4) as u64
    }

    /// Bytes accounted on the simulated link. HQQ deployments second-level
    /// quantize scale/zero to 8 bit (the paper's "scale group size"); we
    /// keep f32 in RAM for kernel convenience but account 1 byte each on
    /// the wire, matching the paper's ~2.6-effective-bits arithmetic.
    pub fn transfer_bytes(&self) -> u64 {
        (self.packed.len() + self.scale.len() + self.zero.len()) as u64
    }

    /// Unpack codes to byte-per-code (kernel input layout).
    pub fn unpack_codes(&self) -> Result<Vec<u8>> {
        bitpack::unpack(&self.packed, self.n_in * self.n_out, self.bits)
    }

    /// Dequantize back to f32 (reference path / attention weights).
    pub fn dequantize(&self) -> Result<Tensor> {
        let codes = self.unpack_codes()?;
        let g = self.group_size;
        let mut data = vec![0.0f32; self.n_in * self.n_out];
        for i in 0..self.n_in {
            let gi = i / g;
            for j in 0..self.n_out {
                let meta = gi * self.n_out + j;
                data[i * self.n_out + j] =
                    (codes[i * self.n_out + j] as f32 - self.zero[meta]) * self.scale[meta];
            }
        }
        Tensor::new(data, vec![self.n_in, self.n_out])
    }
}

/// Quantize a row-major `[n_in, n_out]` matrix.
pub fn quantize(w: &Tensor, cfg: &HqqConfig) -> Result<QuantizedMatrix> {
    if w.rank() != 2 {
        return Err(Error::Quant(format!("expected rank-2 weight, got {:?}", w.shape)));
    }
    let (n_in, n_out) = (w.shape[0], w.shape[1]);
    let g = cfg.group_size;
    if n_in % g != 0 {
        return Err(Error::Quant(format!("n_in {n_in} not divisible by group {g}")));
    }
    if !(1..=8).contains(&cfg.bits) {
        return Err(Error::Quant(format!("bits {} out of range", cfg.bits)));
    }
    let n_groups = n_in / g;
    let qmax = (1u32 << cfg.bits) as f64 - 1.0;

    let mut scale = vec![0.0f32; n_groups * n_out];
    let mut zero = vec![0.0f32; n_groups * n_out];
    let mut codes = vec![0u8; n_in * n_out];

    // column-strided group views: group (gi, j) covers rows gi*g..(gi+1)*g
    let mut wg = vec![0.0f64; g];
    for gi in 0..n_groups {
        for j in 0..n_out {
            for (t, row) in (gi * g..(gi + 1) * g).enumerate() {
                wg[t] = w.data[row * n_out + j] as f64;
            }
            let (s, z) = fit_group(&wg, qmax, cfg);
            let meta = gi * n_out + j;
            scale[meta] = s as f32;
            zero[meta] = z as f32;
            for (t, row) in (gi * g..(gi + 1) * g).enumerate() {
                let q = round_half_even(wg[t] / s + z).clamp(0.0, qmax);
                codes[row * n_out + j] = q as u8;
            }
        }
    }

    let packed = bitpack::pack(&codes, cfg.bits)?;
    Ok(QuantizedMatrix {
        packed,
        scale,
        zero,
        n_in,
        n_out,
        bits: cfg.bits,
        group_size: g,
    })
}

/// Fit (scale, zero) for one group. Min/max init, then HQQ half-quadratic
/// refinement of the zero point.
fn fit_group(wg: &[f64], qmax: f64, cfg: &HqqConfig) -> (f64, f64) {
    let wmin = wg.iter().cloned().fold(f64::INFINITY, f64::min);
    let wmax = wg.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut s = (wmax - wmin) / qmax;
    if s <= 1e-12 {
        s = 1.0; // constant group: codes all zero after rounding w/s + z
    }
    let mut z = -wmin / s;
    if cfg.refine_iters == 0 {
        return (s, z);
    }

    let mut beta = cfg.beta;
    let mut q = vec![0.0f64; wg.len()];
    for _ in 0..cfg.refine_iters {
        // 1) quantize with current (s, z)
        for (qi, &w) in q.iter_mut().zip(wg) {
            *qi = (w / s + z).round().clamp(0.0, qmax);
        }
        // 2) error prox: generalized soft threshold of e = w - s*(q - z)
        //    under the lp prior (HQQ eq. 6)
        let mut z_acc = 0.0;
        for (qi, &w) in q.iter().zip(wg) {
            let recon = s * (qi - z);
            let e = w - recon;
            let e_shrunk = shrink_lp(e, beta, cfg.lp_norm);
            // 3) closed-form zero update contribution:
            //    z* = mean(q - (w - e)/s)
            z_acc += qi - (w - e_shrunk) / s;
        }
        let z_new = z_acc / wg.len() as f64;
        if (z_new - z).abs() < 1e-10 {
            break;
        }
        z = z_new;
        beta *= cfg.kappa;
    }
    (s, z)
}

/// numpy-compatible rounding (round half to even) so codes match the
/// python oracle bit-for-bit.
fn round_half_even(x: f64) -> f64 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        (x / 2.0).round() * 2.0
    } else {
        r
    }
}

/// Generalized soft-threshold: prox of beta‖·‖_p, the HQQ error shrinkage.
fn shrink_lp(x: f64, beta: f64, p: f64) -> f64 {
    let mag = x.abs();
    if mag < 1e-12 {
        return 0.0;
    }
    let t = mag - (p / beta) * mag.powf(p - 1.0);
    if t <= 0.0 {
        0.0
    } else {
        x.signum() * t
    }
}

/// Mean squared reconstruction error (quality metric for tests/benches).
pub fn mse(w: &Tensor, q: &QuantizedMatrix) -> Result<f64> {
    let deq = q.dequantize()?;
    let n = w.data.len() as f64;
    Ok(w
        .data
        .iter()
        .zip(&deq.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};
    use crate::util::rng::Rng;

    fn random_weight(rng: &mut Rng, n_in: usize, n_out: usize, scale: f64) -> Tensor {
        let data: Vec<f32> = (0..n_in * n_out)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        Tensor::new(data, vec![n_in, n_out]).unwrap()
    }

    #[test]
    fn rejects_bad_inputs() {
        let w = Tensor::zeros(vec![30, 8]);
        assert!(quantize(&w, &HqqConfig::plain(4, 16)).is_err()); // 30 % 16
        let w = Tensor::zeros(vec![32, 8]);
        assert!(quantize(&w, &HqqConfig::plain(0, 16)).is_err());
        let w1 = Tensor::zeros(vec![8]);
        assert!(quantize(&w1, &HqqConfig::plain(4, 8)).is_err()); // rank 1
    }

    #[test]
    fn constant_matrix_is_exact() {
        let w = Tensor::new(vec![0.37; 32 * 4], vec![32, 4]).unwrap();
        for bits in [2u8, 3, 4] {
            let q = quantize(&w, &HqqConfig::plain(bits, 16)).unwrap();
            let deq = q.dequantize().unwrap();
            assert!(w.max_abs_diff(&deq) < 1e-5, "bits={bits}");
        }
    }

    #[test]
    fn prop_minmax_error_bound() {
        // plain min/max affine quant: |w - deq| <= scale/2 per element
        check(
            "hqq-minmax-bound",
            60,
            |r| {
                let bits = [2u8, 3, 4][r.below(3)];
                let g = [8usize, 16][r.below(2)];
                let n_out = r.range(1, 6);
                let n_groups = r.range(1, 4);
                let w = random_weight(r, g * n_groups, n_out, 0.5);
                (bits, g, w)
            },
            |(bits, g, w)| {
                let q = quantize(w, &HqqConfig::plain(*bits, *g)).map_err(|e| e.to_string())?;
                let deq = q.dequantize().map_err(|e| e.to_string())?;
                let n_out = w.shape[1];
                for i in 0..w.shape[0] {
                    for j in 0..n_out {
                        let meta = (i / g) * n_out + j;
                        let bound = q.scale[meta].abs() / 2.0 + 1e-4;
                        let err = (w.data[i * n_out + j] - deq.data[i * n_out + j]).abs();
                        ensure(err <= bound, format!("err {err} > bound {bound} at ({i},{j})"))?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn refinement_does_not_hurt_much_and_usually_helps() {
        // HQQ refinement should reduce (or at worst match) MSE on weights
        // with outliers — the case it is designed for.
        let mut rng = Rng::new(9);
        let mut wins = 0;
        let trials = 20;
        for _ in 0..trials {
            let mut w = random_weight(&mut rng, 64, 16, 0.3);
            // inject outliers
            for _ in 0..20 {
                let i = rng.below(w.data.len());
                w.data[i] *= 8.0;
            }
            let plain = quantize(&w, &HqqConfig::plain(3, 16)).unwrap();
            let hqq = quantize(&w, &HqqConfig::new(3, 16)).unwrap();
            let (m_plain, m_hqq) = (mse(&w, &plain).unwrap(), mse(&w, &hqq).unwrap());
            if m_hqq <= m_plain * 1.001 {
                wins += 1;
            }
        }
        assert!(wins >= trials * 7 / 10, "refinement helped only {wins}/{trials}");
    }

    #[test]
    fn prop_more_bits_less_error() {
        check(
            "hqq-bits-monotone",
            30,
            |r| random_weight(r, 32, 8, 0.4),
            |w| {
                let e2 = mse(w, &quantize(w, &HqqConfig::plain(2, 16)).unwrap()).unwrap();
                let e4 = mse(w, &quantize(w, &HqqConfig::plain(4, 16)).unwrap()).unwrap();
                ensure(e4 <= e2 + 1e-9, format!("e4 {e4} > e2 {e2}"))
            },
        );
    }

    #[test]
    fn transfer_bytes_accounting() {
        let mut rng = Rng::new(2);
        let w = random_weight(&mut rng, 128, 256, 0.2);
        let q = quantize(&w, &HqqConfig::plain(2, 16)).unwrap();
        let n = 128 * 256;
        assert_eq!(q.packed.len(), n * 2 / 8);
        assert_eq!(q.scale.len(), (128 / 16) * 256);
        assert_eq!(
            q.transfer_bytes(),
            (n * 2 / 8 + 2 * (128 / 16) * 256) as u64
        );
        assert!(q.stored_bytes() > q.transfer_bytes());
    }

    #[test]
    fn matches_python_oracle_fixture() {
        // pinned fixture: python kernels/ref.py::quantize_group on a fixed
        // deterministic matrix (see python/tests/test_cross_language.py,
        // which regenerates and checks the same values).
        let n_in = 8;
        let n_out = 2;
        let data: Vec<f32> = (0..16).map(|i| ((i * 7 % 16) as f32 - 8.0) / 4.0).collect();
        let w = Tensor::new(data, vec![n_in, n_out]).unwrap();
        let q = quantize(&w, &HqqConfig::plain(4, 4)).unwrap();
        let codes = q.unpack_codes().unwrap();
        // python: ref.quantize_group(w, bits=4, group_size=4)
        let expected_codes = [0u8, 15, 15, 10, 13, 5, 11, 0, 15, 15, 10, 10, 5, 5, 0, 0];
        assert_eq!(codes, expected_codes, "codes diverged from python oracle");
        let expected_scale = [0.23333333f32, 0.1, 0.1, 0.1];
        for (got, want) in q.scale.iter().zip(expected_scale) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
        let expected_zero = [8.571428f32, 17.5, 15.0, -2.5];
        for (got, want) in q.zero.iter().zip(expected_zero) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }
}
