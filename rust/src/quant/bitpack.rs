//! Bit-packing codecs for 2/3/4-bit quantization codes.
//!
//! Codes are packed little-endian within a contiguous bit stream: code `i`
//! occupies bits `[i*b, (i+1)*b)`. This is the layout the host "pinned"
//! expert buffers use — what actually crosses the (simulated) PCIe link —
//! and it is unpacked to byte-per-code right before kernel dispatch (the
//! GPU-side unpack the fused kernel performs in HBM on real hardware).

use crate::error::{Error, Result};

/// Number of bytes needed to pack `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each < 2^bits) into a bit stream.
pub fn pack(codes: &[u8], bits: u8) -> Result<Vec<u8>> {
    if !(1..=8).contains(&bits) {
        return Err(Error::Quant(format!("bits must be 1..=8, got {bits}")));
    }
    let limit = if bits == 8 { 255 } else { (1u16 << bits) as u8 - 1 };
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    for (i, &c) in codes.iter().enumerate() {
        if c > limit {
            return Err(Error::Quant(format!(
                "code {c} exceeds {bits}-bit range at index {i}"
            )));
        }
        let bit = i * bits as usize;
        let byte = bit / 8;
        let off = bit % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
    }
    Ok(out)
}

/// Unpack `n` codes of `bits` width from a bit stream.
pub fn unpack(packed: &[u8], n: usize, bits: u8) -> Result<Vec<u8>> {
    if !(1..=8).contains(&bits) {
        return Err(Error::Quant(format!("bits must be 1..=8, got {bits}")));
    }
    if packed.len() < packed_len(n, bits) {
        return Err(Error::Quant(format!(
            "packed buffer too short: {} < {}",
            packed.len(),
            packed_len(n, bits)
        )));
    }
    let mask = if bits == 8 { 0xffu16 } else { (1u16 << bits) - 1 };
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let bit = i * bits as usize;
        let byte = bit / 8;
        let off = bit % 8;
        let mut v = (packed[byte] >> off) as u16;
        if off + bits as usize > 8 {
            v |= (packed[byte + 1] as u16) << (8 - off);
        }
        out.push((v & mask) as u8);
    }
    Ok(out)
}

/// Unpack directly into a reusable buffer (hot-path variant: the decode
/// loop calls this per expert transfer; no allocation).
pub fn unpack_into(packed: &[u8], n: usize, bits: u8, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(n);
    let mask = if bits == 8 { 0xffu16 } else { (1u16 << bits) - 1 };
    if packed.len() < packed_len(n, bits) {
        return Err(Error::Quant("packed buffer too short".into()));
    }
    for i in 0..n {
        let bit = i * bits as usize;
        let byte = bit / 8;
        let off = bit % 8;
        let mut v = (packed[byte] >> off) as u16;
        if off + bits as usize > 8 {
            v |= (packed[byte + 1] as u16) << (8 - off);
        }
        out.push((v & mask) as u8);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(8, 2), 2);
        assert_eq!(packed_len(8, 3), 3);
        assert_eq!(packed_len(3, 3), 2); // 9 bits -> 2 bytes
        assert_eq!(packed_len(0, 4), 0);
    }

    #[test]
    fn rejects_out_of_range_codes() {
        assert!(pack(&[4], 2).is_err());
        assert!(pack(&[8], 3).is_err());
        assert!(pack(&[3], 2).is_ok());
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(pack(&[0], 0).is_err());
        assert!(pack(&[0], 9).is_err());
        assert!(unpack(&[0], 1, 0).is_err());
    }

    #[test]
    fn rejects_short_buffer() {
        assert!(unpack(&[0u8; 2], 100, 3).is_err());
    }

    #[test]
    fn known_vector_2bit() {
        // codes 0,1,2,3 -> byte 0b11100100
        let packed = pack(&[0, 1, 2, 3], 2).unwrap();
        assert_eq!(packed, vec![0b1110_0100]);
        assert_eq!(unpack(&packed, 4, 2).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn known_vector_3bit_crosses_bytes() {
        // 7,7,7 = 0b111_111_111 -> bytes 0xFF, 0x01
        let packed = pack(&[7, 7, 7], 3).unwrap();
        assert_eq!(packed, vec![0xff, 0x01]);
        assert_eq!(unpack(&packed, 3, 3).unwrap(), vec![7, 7, 7]);
    }

    #[test]
    fn prop_roundtrip_all_widths() {
        check(
            "bitpack-roundtrip",
            300,
            |r| {
                let bits = [2u8, 3, 4, 8][r.below(4)];
                let n = r.range(0, 200);
                let max = if bits == 8 { 256 } else { 1usize << bits };
                let codes: Vec<u8> = (0..n).map(|_| r.below(max) as u8).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let packed = pack(codes, *bits).map_err(|e| e.to_string())?;
                ensure(
                    packed.len() == packed_len(codes.len(), *bits),
                    "packed length mismatch",
                )?;
                let back = unpack(&packed, codes.len(), *bits).map_err(|e| e.to_string())?;
                ensure(&back == codes, "roundtrip mismatch")
            },
        );
    }

    #[test]
    fn unpack_into_reuses_buffer() {
        let packed = pack(&[1, 2, 3, 0, 1], 2).unwrap();
        let mut buf = Vec::new();
        unpack_into(&packed, 5, 2, &mut buf).unwrap();
        assert_eq!(buf, vec![1, 2, 3, 0, 1]);
        let cap = buf.capacity();
        unpack_into(&packed, 5, 2, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap); // no realloc
    }
}
