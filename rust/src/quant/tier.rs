//! Per-expert precision tiers: hotness-aware quantization.
//!
//! The link — not FLOPs — bounds offloaded MoE decoding, and routing is
//! heavily skewed: a few experts per layer serve most tokens. Uniform
//! quantization therefore overspends link bytes on experts that are
//! almost never shipped, and underspends on the ones shipped constantly.
//! A [`TierPolicy`] splits each layer's experts into three tiers by
//! routing hotness:
//!
//! * **Hot** — frequently routed; kept at HIGHER precision (more bits,
//!   more bytes) because they are usually cache-resident anyway, so
//!   their extra bytes rarely cross the link while their quality affects
//!   most tokens.
//! * **Warm** — the middle; stays at the deployment's base
//!   `expert_quant` scheme.
//! * **Cold** — rarely routed; quantized HARDER (fewer bits), so the
//!   misses they do cause ship fewer bytes.
//!
//! Tier assignment is seeded statically from gate statistics (the router
//! weight matrix tells which experts the gate prefers before a single
//! token runs) and optionally re-ranked online from the per-expert route
//! counters the LRU cache exports ([`crate::cache::lru::LruSet`]
//! hit/use counts, aggregated by [`crate::cache::manager::CacheManager`]).
//!
//! The policy is opt-out by construction: `enabled = false` (the
//! default) makes every expert Warm at the base scheme — byte-identical
//! to the uniform deployment.

use crate::config::QuantScheme;
use crate::error::{Error, Result};

/// An expert's precision tier. Ordered `Cold < Warm < Hot` so "promotion"
/// (toward more bits) and "demotion" compare naturally.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    Cold,
    Warm,
    Hot,
}

impl Tier {
    pub fn label(&self) -> &'static str {
        match self {
            Tier::Cold => "cold",
            Tier::Warm => "warm",
            Tier::Hot => "hot",
        }
    }
}

/// The hot/warm/cold precision policy, carried by
/// [`crate::config::ServingConfig::expert_tiers`].
///
/// Warm experts always use the deployment's base `expert_quant` scheme;
/// only the hot and cold schemes are configured here. Fractions are of
/// each LAYER's expert count (tiers are per-layer — hotness ranks
/// experts against their own layer's siblings, matching how routing
/// skew manifests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierPolicy {
    /// Master switch. Off (default) = every expert Warm at the base
    /// scheme, byte-identical to the uniform deployment.
    pub enabled: bool,
    /// Scheme for the hot tier (default 4-bit HQQ).
    pub hot: QuantScheme,
    /// Scheme for the cold tier (default 2-bit HQQ).
    pub cold: QuantScheme,
    /// Fraction of each layer's experts assigned Hot (floor'd).
    pub hot_fraction: f64,
    /// Fraction of each layer's experts assigned Cold (floor'd, clamped
    /// so hot + cold never exceeds the layer).
    pub cold_fraction: f64,
    /// Re-rank tiers online from the cache's per-expert route counters
    /// every `adapt_interval` routed expert-uses (tick-boundary safe: a
    /// re-staged expert always lands at its CURRENT tier's precision).
    pub adaptive: bool,
    /// Routed uses between adaptation passes.
    pub adapt_interval: u64,
}

impl Default for TierPolicy {
    fn default() -> Self {
        TierPolicy {
            enabled: false,
            hot: QuantScheme::Hqq { bits: 4 },
            cold: QuantScheme::Hqq { bits: 2 },
            hot_fraction: 0.25,
            cold_fraction: 0.25,
            adaptive: true,
            adapt_interval: 256,
        }
    }
}

impl TierPolicy {
    /// A ready-to-use hot/warm/cold policy (the bench/eval sweep point):
    /// 4-bit hot, 2-bit cold, a quarter of each layer in each.
    pub fn hot_cold() -> Self {
        TierPolicy { enabled: true, ..Default::default() }
    }

    /// The scheme an expert at `tier` is packed with, given the
    /// deployment's base (warm) scheme. With the policy disabled every
    /// tier resolves to the base scheme.
    pub fn scheme_for(&self, tier: Tier, base: QuantScheme) -> QuantScheme {
        if !self.enabled {
            return base;
        }
        match tier {
            Tier::Hot => self.hot,
            Tier::Warm => base,
            Tier::Cold => self.cold,
        }
    }

    /// Structural validation — called from `ServingConfig::validate`
    /// ONLY when enabled (inert knobs must not reject a config).
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        for (name, f) in [("hot_fraction", self.hot_fraction), ("cold_fraction", self.cold_fraction)]
        {
            if !f.is_finite() || !(0.0..=1.0).contains(&f) {
                return Err(Error::Config(format!(
                    "{name} {f} must be a fraction in [0, 1]"
                )));
            }
        }
        if self.hot_fraction + self.cold_fraction > 1.0 {
            return Err(Error::Config(format!(
                "hot_fraction {} + cold_fraction {} exceeds 1.0 — the tiers \
                 would overlap",
                self.hot_fraction, self.cold_fraction
            )));
        }
        if self.adaptive && self.adapt_interval == 0 {
            return Err(Error::Config(
                "adapt_interval must be >= 1 with adaptive tiers on".into(),
            ));
        }
        Ok(())
    }
}

/// Rank one layer's experts by hotness `scores` and assign tiers: the
/// top `floor(hot_fraction * E)` become Hot, the bottom
/// `floor(cold_fraction * E)` become Cold (clamped so the two never
/// overlap), everything between stays Warm.
///
/// Deterministic: ties break toward the LOWER expert index (stable rank
/// by descending score, ascending index), so equal gate statistics
/// always produce the same assignment.
pub fn assign_tiers(scores: &[f64], hot_fraction: f64, cold_fraction: f64) -> Vec<Tier> {
    let e = scores.len();
    let mut order: Vec<usize> = (0..e).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let hot_n = ((hot_fraction.clamp(0.0, 1.0) * e as f64).floor() as usize).min(e);
    let cold_n = ((cold_fraction.clamp(0.0, 1.0) * e as f64).floor() as usize).min(e - hot_n);
    let mut tiers = vec![Tier::Warm; e];
    for &i in order.iter().take(hot_n) {
        tiers[i] = Tier::Hot;
    }
    for &i in order.iter().rev().take(cold_n) {
        tiers[i] = Tier::Cold;
    }
    tiers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn disabled_policy_resolves_every_tier_to_base() {
        let p = TierPolicy::default();
        assert!(!p.enabled);
        let base = QuantScheme::Hqq { bits: 3 };
        for t in [Tier::Hot, Tier::Warm, Tier::Cold] {
            assert_eq!(p.scheme_for(t, base), base);
        }
    }

    #[test]
    fn enabled_policy_maps_tiers_to_schemes() {
        let p = TierPolicy::hot_cold();
        let base = QuantScheme::Hqq { bits: 3 };
        assert_eq!(p.scheme_for(Tier::Hot, base), QuantScheme::Hqq { bits: 4 });
        assert_eq!(p.scheme_for(Tier::Warm, base), base);
        assert_eq!(p.scheme_for(Tier::Cold, base), QuantScheme::Hqq { bits: 2 });
    }

    #[test]
    fn assignment_follows_scores() {
        // 8 experts, quarter hot / quarter cold: top-2 hot, bottom-2 cold
        let scores = [0.5, 3.0, 0.1, 2.0, 1.0, 0.9, 0.2, 0.4];
        let tiers = assign_tiers(&scores, 0.25, 0.25);
        assert_eq!(tiers[1], Tier::Hot);
        assert_eq!(tiers[3], Tier::Hot);
        assert_eq!(tiers[2], Tier::Cold);
        assert_eq!(tiers[6], Tier::Cold);
        assert_eq!(tiers.iter().filter(|t| **t == Tier::Warm).count(), 4);
    }

    #[test]
    fn zero_fractions_are_all_warm() {
        let tiers = assign_tiers(&[1.0, 2.0, 3.0, 4.0], 0.0, 0.0);
        assert!(tiers.iter().all(|t| *t == Tier::Warm));
    }

    #[test]
    fn ties_break_deterministically_toward_lower_index() {
        let tiers = assign_tiers(&[1.0, 1.0, 1.0, 1.0], 0.25, 0.25);
        assert_eq!(tiers[0], Tier::Hot, "lowest index wins the hot slot on ties");
        assert_eq!(tiers[3], Tier::Cold, "highest index loses to the cold slot on ties");
    }

    #[test]
    fn prop_assignment_invariants() {
        // 1) tier counts match the floor'd fractions (clamped to E);
        // 2) every Hot expert scores >= every Warm expert, every Warm
        //    >= every Cold (up to rank ties);
        // 3) the assignment is deterministic.
        check(
            "tier-assignment-invariants",
            200,
            |r| {
                let e = 1 + r.below(16);
                let scores: Vec<f64> = (0..e).map(|_| r.below(8) as f64).collect();
                let hf = r.below(5) as f64 / 4.0;
                let cf = r.below(5) as f64 / 4.0;
                (scores, hf, cf)
            },
            |(scores, hf, cf)| {
                let e = scores.len();
                let tiers = assign_tiers(scores, *hf, *cf);
                ensure(tiers.len() == e, "one tier per expert")?;
                let hot_n = ((hf * e as f64).floor() as usize).min(e);
                let cold_n = ((cf * e as f64).floor() as usize).min(e - hot_n);
                let hots = tiers.iter().filter(|t| **t == Tier::Hot).count();
                let colds = tiers.iter().filter(|t| **t == Tier::Cold).count();
                ensure(hots == hot_n, "hot count")?;
                ensure(colds == cold_n, "cold count")?;
                let min_hot = tiers
                    .iter()
                    .zip(scores)
                    .filter(|(t, _)| **t == Tier::Hot)
                    .map(|(_, s)| *s)
                    .fold(f64::INFINITY, f64::min);
                let max_cold = tiers
                    .iter()
                    .zip(scores)
                    .filter(|(t, _)| **t == Tier::Cold)
                    .map(|(_, s)| *s)
                    .fold(f64::NEG_INFINITY, f64::max);
                for (t, s) in tiers.iter().zip(scores) {
                    match t {
                        Tier::Warm => {
                            ensure(*s <= min_hot, "warm scored above a hot expert")?;
                            ensure(*s >= max_cold, "warm scored below a cold expert")?;
                        }
                        Tier::Hot => ensure(*s >= max_cold, "hot below a cold")?,
                        Tier::Cold => ensure(*s <= min_hot, "cold above a hot")?,
                    }
                }
                ensure(
                    assign_tiers(scores, *hf, *cf) == tiers,
                    "assignment not deterministic",
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn policy_validation() {
        assert!(TierPolicy::default().validate().is_ok());
        assert!(TierPolicy::hot_cold().validate().is_ok());
        let bad_frac = TierPolicy { hot_fraction: 1.5, ..TierPolicy::hot_cold() };
        assert!(bad_frac.validate().is_err());
        let nan_frac = TierPolicy { cold_fraction: f64::NAN, ..TierPolicy::hot_cold() };
        assert!(nan_frac.validate().is_err());
        let overlap =
            TierPolicy { hot_fraction: 0.6, cold_fraction: 0.6, ..TierPolicy::hot_cold() };
        assert!(overlap.validate().is_err());
        let zero_interval =
            TierPolicy { adaptive: true, adapt_interval: 0, ..TierPolicy::hot_cold() };
        assert!(zero_interval.validate().is_err());
        // inert-when-off: invalid knobs behind the off switch don't reject
        let inert = TierPolicy { enabled: false, hot_fraction: 9.0, ..TierPolicy::default() };
        assert!(inert.validate().is_ok());
    }
}
