//! Quantization substrate: bit-packing codecs and the HQQ group quantizer.
//!
//! The paper compresses Mixtral's experts with HQQ (Badri & Shaji 2023) at
//! 2–4 bits and streams the *compressed* bytes over PCIe. We mirror that:
//! `hqq` produces (codes, scale, zero) per group, `bitpack` packs codes to
//! their logical width for host storage / link accounting, and
//! `QuantizedMatrix` bundles it all with exact byte accounting.

pub mod bitpack;
pub mod hqq;

pub use hqq::{HqqConfig, QuantizedMatrix};
