//! Quantization substrate: bit-packing codecs, the HQQ group quantizer,
//! and the per-expert precision tier policy.
//!
//! The paper compresses Mixtral's experts with HQQ (Badri & Shaji 2023) at
//! 2–4 bits and streams the *compressed* bytes over PCIe. We mirror that:
//! `hqq` produces (codes, scale, zero) per group, `bitpack` packs codes to
//! their logical width for host storage / link accounting, and
//! `QuantizedMatrix` bundles it all with exact byte accounting.
//!
//! ## Tier → bits → bytes-over-link
//!
//! `tier` makes precision a PER-EXPERT property instead of a global one.
//! Each expert carries a [`tier::Tier`] (hot / warm / cold, ranked by
//! routing hotness); the [`tier::TierPolicy`] maps tiers to
//! [`crate::config::QuantScheme`]s (default hot → 4-bit, warm → the
//! deployment's base `expert_quant`, cold → 2-bit). The scheme's bits
//! decide the packed-code width and group size, and therefore the exact
//! bytes that cross the host→device link when THAT expert misses:
//! `QuantScheme::bytes_for(n, g) = ceil(n·bits/8) + ceil(n/g)·2` per
//! matrix (u8 scale + u8 zero per group). The host pool stores one
//! packed copy per DISTINCT tier scheme, the cost model prices each
//! transfer at the expert's current tier bytes, and the cache manager
//! tracks the bit-width each resident copy was staged at so a tier
//! change forces a re-stage — never a stale-precision kernel call.
//! Rarely-routed (cold) experts thus ship fewer bytes on the misses
//! they do cause, while hot experts — mostly cache-resident — keep more
//! precision where quality matters most.

pub mod bitpack;
pub mod hqq;
pub mod tier;

pub use hqq::{HqqConfig, QuantizedMatrix};
pub use tier::{assign_tiers, Tier, TierPolicy};
