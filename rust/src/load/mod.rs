//! Trace-replay load harness: declarative workload profiles replayed
//! against the [`crate::coordinator`] with per-request latency sampling
//! and SLO-attainment reporting.
//!
//! A [`WorkloadProfile`] declares everything a load run needs — request
//! count, Poisson arrival process (optionally bursty), prompt shape,
//! scheduler knobs (width, prefix cache, chunked prefill), and the SLO
//! targets the run is judged against. [`run_profile`] replays it:
//! requests are submitted on the sampled arrival schedule, each finished
//! stream contributes a client-side TTFT (`queue_wait_s + ttft_s`), a
//! TPOT (`(wall_s - ttft_s) / (new_tokens - 1)`), and its queue wait,
//! and the percentiles of those samples are compared against the
//! declared targets. The engine runs with span tracing on, so the
//! report also embeds the [`crate::trace::analysis`] output for the
//! run: aggregate bottleneck attribution and counterfactual what-if
//! speedups, fetched through [`crate::coordinator::Coordinator::analyze`].
//!
//! Three built-in profiles mirror common serving shapes:
//! * [`bursty`] — short independent prompts on a bursty Poisson process
//!   (phases alternate between `rate` and `rate * burst_factor`);
//! * [`chat`] — multi-turn conversations where every turn's prompt
//!   extends the previous one, so consecutive admissions hit the prefix
//!   cache;
//! * [`rag`] — long-context prompts sharing one retrieved context,
//!   prefilled in chunks.
//!
//! All prompt generation is deterministic in the profile's seed, and
//! every prompt stays well under the tiny model's 512-position window
//! (1 byte = 1 token).

use std::path::Path;
use std::time::Duration;

use crate::config::{HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use crate::coordinator::{collect_events_timeout, Coordinator, Event, Request};
use crate::error::Result;
use crate::fault::FaultPlan;
use crate::harness;
use crate::telemetry::percentile;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-profile latency targets, in wall seconds. Attainment is reported,
/// never asserted — a missed SLO is a finding, not a failure.
#[derive(Debug, Clone, Copy)]
pub struct SloTargets {
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
}

/// What the prompts of a profile look like.
#[derive(Debug, Clone, Copy)]
pub enum PromptShape {
    /// Independent short prompts of `min_words..=max_words` words.
    Bursty { min_words: usize, max_words: usize },
    /// `users` conversations of `turns` turns each; turn `t+1`'s prompt
    /// extends turn `t`'s, so the prefix cache can seed every follow-up.
    Chat { users: usize, turns: usize },
    /// One shared retrieved context of roughly `context_words` words,
    /// followed by a per-request question.
    Rag { context_words: usize },
}

/// A declarative load-run: arrival process + prompt shape + scheduler
/// knobs + SLO targets. See the module docs for the built-in instances.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub name: String,
    /// Total requests to replay.
    pub requests: usize,
    /// Base Poisson arrival rate (requests per wall second).
    pub arrival_rate_per_s: f64,
    /// Rate multiplier during burst phases (1.0 = plain Poisson).
    pub burst_factor: f64,
    /// Requests per phase; phases alternate burst / calm.
    pub burst_len: usize,
    /// Token budget per request.
    pub max_tokens: usize,
    /// Continuous-batching width the coordinator runs at.
    pub width: usize,
    pub prefix_cache: bool,
    pub chunked_prefill: bool,
    pub prompt: PromptShape,
    pub slo: SloTargets,
    pub seed: u64,
    /// Seeded fault-injection plan the replay runs under (see
    /// [`crate::fault`]). Disabled for every profile except [`chaos`],
    /// keeping their replays byte-identical to a fault-free build.
    pub faults: FaultPlan,
}

/// Arrival gaps are clamped here so one unlucky exponential tail cannot
/// stall a replay for seconds.
const MAX_GAP_S: f64 = 0.5;

/// Small word pool the deterministic prompt generator draws from.
const WORDS: &[&str] = &[
    "expert", "router", "cache", "layer", "token", "prefetch", "link", "batch", "prefix",
    "decode", "memory", "offload", "gate", "tier", "stream", "model",
];

impl WorkloadProfile {
    /// The serving configuration this profile replays against. Tracing
    /// is always on (the report needs the span ring), and suffix
    /// stopping is disabled so token counts depend only on the budget —
    /// TPOT samples then measure the scheduler, not the sampler's luck.
    pub fn serving_config(&self) -> ServingConfig {
        ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            max_concurrent_sessions: self.width,
            max_new_tokens: self.max_tokens,
            prefix_cache: self.prefix_cache,
            chunked_prefill: self.chunked_prefill,
            stop_suffix: String::new(),
            trace: true,
            faults: self.faults.clone(),
            ..Default::default()
        }
    }

    /// Seconds between consecutive submissions: exponential gaps at the
    /// phase rate, phases of `burst_len` requests alternating between
    /// `rate * burst_factor` (burst) and `rate` (calm).
    pub fn arrival_gaps_s(&self, r: &mut Rng) -> Vec<f64> {
        let burst_len = self.burst_len.max(1);
        (0..self.requests)
            .map(|i| {
                let bursting = (i / burst_len) % 2 == 0;
                let rate = if bursting {
                    self.arrival_rate_per_s * self.burst_factor.max(1e-9)
                } else {
                    self.arrival_rate_per_s
                };
                let u = r.f64();
                (-(1.0 - u).ln() / rate.max(1e-9)).min(MAX_GAP_S)
            })
            .collect()
    }

    /// The `requests` prompt strings, deterministic in the seed. Chat
    /// prompts are emitted turn-major (turn 0 of every user, then turn 1,
    /// …) so each follow-up arrives after the turn it extends finished.
    pub fn prompts(&self) -> Vec<String> {
        let mut r = Rng::new(self.seed ^ 0x10ad);
        let pick = |r: &mut Rng| WORDS[r.below(WORDS.len())];
        match self.prompt {
            PromptShape::Bursty { min_words, max_words } => (0..self.requests)
                .map(|_| {
                    let n = min_words + r.below(max_words.saturating_sub(min_words) + 1);
                    let words: Vec<&str> = (0..n.max(1)).map(|_| pick(&mut r)).collect();
                    format!("explain {}", words.join(" "))
                })
                .collect(),
            PromptShape::Chat { users, turns } => {
                // per-user transcripts; turn t's prompt is a strict
                // prefix of turn t+1's, which is what the prefix cache
                // keys on
                let mut transcripts: Vec<String> = (0..users.max(1))
                    .map(|u| format!("system: be brief. user {u} asks:\n"))
                    .collect();
                let mut out = Vec::with_capacity(self.requests);
                'outer: for t in 0..turns.max(1) {
                    for tr in transcripts.iter_mut() {
                        tr.push_str(&format!("q{t}: about {}?\n", pick(&mut r)));
                        out.push(tr.clone());
                        if out.len() == self.requests {
                            break 'outer;
                        }
                    }
                }
                while out.len() < self.requests {
                    out.push(transcripts[out.len() % transcripts.len()].clone());
                }
                out
            }
            PromptShape::Rag { context_words } => {
                let ctx: Vec<&str> = (0..context_words.max(1)).map(|_| pick(&mut r)).collect();
                let ctx = format!("context: {}.\n", ctx.join(" "));
                (0..self.requests)
                    .map(|_| format!("{ctx}question: what about {}?\n", pick(&mut r)))
                    .collect()
            }
        }
    }
}

/// Bursty short-prompt traffic: independent requests, phases alternating
/// between 3x and 1x the base arrival rate.
pub fn bursty(smoke: bool) -> WorkloadProfile {
    WorkloadProfile {
        name: "bursty".into(),
        requests: if smoke { 6 } else { 24 },
        arrival_rate_per_s: 16.0,
        burst_factor: 3.0,
        burst_len: if smoke { 2 } else { 6 },
        max_tokens: 24,
        width: 4,
        prefix_cache: false,
        chunked_prefill: false,
        prompt: PromptShape::Bursty { min_words: 2, max_words: 8 },
        slo: SloTargets {
            ttft_p50_s: 2.0,
            ttft_p99_s: 8.0,
            tpot_p50_s: 0.5,
            tpot_p99_s: 2.0,
        },
        seed: 11,
        faults: FaultPlan::default(),
    }
}

/// Multi-turn chat with shared prefixes: every follow-up turn extends
/// its conversation's transcript, exercising the prefix cache.
pub fn chat(smoke: bool) -> WorkloadProfile {
    let (users, turns) = if smoke { (2, 2) } else { (4, 4) };
    WorkloadProfile {
        name: "chat".into(),
        requests: users * turns,
        arrival_rate_per_s: 8.0,
        burst_factor: 1.0,
        burst_len: users,
        max_tokens: 16,
        width: 2,
        prefix_cache: true,
        chunked_prefill: false,
        prompt: PromptShape::Chat { users, turns },
        slo: SloTargets {
            ttft_p50_s: 2.0,
            ttft_p99_s: 8.0,
            tpot_p50_s: 0.5,
            tpot_p99_s: 2.0,
        },
        seed: 13,
        faults: FaultPlan::default(),
    }
}

/// Long-context RAG traffic: one shared retrieved context ahead of every
/// question, prefilled in chunks so live decodes keep streaming.
pub fn rag(smoke: bool) -> WorkloadProfile {
    WorkloadProfile {
        name: "rag".into(),
        requests: if smoke { 3 } else { 12 },
        arrival_rate_per_s: 6.0,
        burst_factor: 1.0,
        burst_len: 4,
        max_tokens: 16,
        width: 2,
        prefix_cache: false,
        chunked_prefill: true,
        prompt: PromptShape::Rag { context_words: 40 },
        slo: SloTargets {
            ttft_p50_s: 4.0,
            ttft_p99_s: 12.0,
            tpot_p50_s: 0.5,
            tpot_p99_s: 2.0,
        },
        seed: 17,
        faults: FaultPlan::default(),
    }
}

/// Chaos traffic: the bursty arrival shape replayed under a seeded
/// transient-only fault plan — transfer failures, payload corruption,
/// KV-swap faults and link brownouts all fire, nothing escalates. Every
/// request must still finish (transient faults are recoverable by
/// construction) while `faults_injected` / `transfer_retries` climb and
/// the SLO rows absorb the recovery cost. This is the profile the chaos
/// harness reports into `BENCH_9.json` and the CI chaos smoke runs.
pub fn chaos(smoke: bool) -> WorkloadProfile {
    WorkloadProfile {
        name: "chaos".into(),
        seed: 23,
        faults: FaultPlan::transient_smoke(0xC4A05),
        // recovery time (retries + brownouts) pushes tails well past the
        // clean bursty targets; the chaos SLO is "degraded, not down"
        slo: SloTargets {
            ttft_p50_s: 4.0,
            ttft_p99_s: 16.0,
            tpot_p50_s: 1.0,
            tpot_p99_s: 4.0,
        },
        ..bursty(smoke)
    }
}

/// One finished replay: the raw latency samples plus the span-ring
/// analysis the coordinator returned for the run.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub name: String,
    pub requests: usize,
    pub requests_ok: usize,
    pub requests_failed: usize,
    /// Client-side time to first token: queue wait + admission-to-token.
    pub ttft_s: Vec<f64>,
    /// Time per output token after the first.
    pub tpot_s: Vec<f64>,
    pub queue_s: Vec<f64>,
    pub slo: SloTargets,
    /// Faults injected during the run (engine-lifetime; 0 faults-off).
    pub faults_injected: u64,
    /// Transient transfer retries charged to the virtual link.
    pub transfer_retries: u64,
    /// Requests cancelled for exceeding their deadline.
    pub deadline_cancellations: u64,
    /// [`crate::trace::analysis::analyze_response`] output for the run.
    pub analysis: Json,
}

impl ProfileReport {
    /// The BENCH_8 row for this profile: sample percentiles beside their
    /// targets with per-percentile attainment booleans, plus the run's
    /// bottleneck attribution and what-if projections.
    pub fn to_json(&self) -> Json {
        let ttft_p50 = percentile(&self.ttft_s, 0.50);
        let ttft_p99 = percentile(&self.ttft_s, 0.99);
        let tpot_p50 = percentile(&self.tpot_s, 0.50);
        let tpot_p99 = percentile(&self.tpot_s, 0.99);
        let attribution = self.analysis.get("attribution").cloned().unwrap_or(Json::Null);
        let whatif = self.analysis.get("whatif").cloned().unwrap_or(Json::Null);
        Json::obj(vec![
            ("profile", Json::str(&self.name)),
            ("requests", self.requests.into()),
            ("requests_ok", self.requests_ok.into()),
            ("requests_failed", self.requests_failed.into()),
            ("ttft_p50_s", ttft_p50.into()),
            ("ttft_p99_s", ttft_p99.into()),
            ("ttft_p50_target_s", self.slo.ttft_p50_s.into()),
            ("ttft_p99_target_s", self.slo.ttft_p99_s.into()),
            ("ttft_p50_attained", (ttft_p50 <= self.slo.ttft_p50_s).into()),
            ("ttft_p99_attained", (ttft_p99 <= self.slo.ttft_p99_s).into()),
            ("tpot_p50_s", tpot_p50.into()),
            ("tpot_p99_s", tpot_p99.into()),
            ("tpot_p50_target_s", self.slo.tpot_p50_s.into()),
            ("tpot_p99_target_s", self.slo.tpot_p99_s.into()),
            ("tpot_p50_attained", (tpot_p50 <= self.slo.tpot_p50_s).into()),
            ("tpot_p99_attained", (tpot_p99 <= self.slo.tpot_p99_s).into()),
            ("queue_p50_s", percentile(&self.queue_s, 0.50).into()),
            ("queue_p99_s", percentile(&self.queue_s, 0.99).into()),
            ("faults_injected", (self.faults_injected as usize).into()),
            ("transfer_retries", (self.transfer_retries as usize).into()),
            ("deadline_cancellations", (self.deadline_cancellations as usize).into()),
            ("attribution", attribution),
            ("whatif", whatif),
        ])
    }

    /// One human-readable line per run, for the harness console output.
    pub fn summary(&self) -> String {
        let mark = |attained: bool| if attained { "ok" } else { "MISS" };
        let ttft_p50 = percentile(&self.ttft_s, 0.50);
        let ttft_p99 = percentile(&self.ttft_s, 0.99);
        let tpot_p50 = percentile(&self.tpot_s, 0.50);
        let tpot_p99 = percentile(&self.tpot_s, 0.99);
        format!(
            "{}: {}/{} ok | ttft p50 {:.3}s ({}) p99 {:.3}s ({}) | tpot p50 {:.4}s ({}) p99 {:.4}s ({})",
            self.name,
            self.requests_ok,
            self.requests,
            ttft_p50,
            mark(ttft_p50 <= self.slo.ttft_p50_s),
            ttft_p99,
            mark(ttft_p99 <= self.slo.ttft_p99_s),
            tpot_p50,
            mark(tpot_p50 <= self.slo.tpot_p50_s),
            tpot_p99,
            mark(tpot_p99 <= self.slo.tpot_p99_s),
        )
    }
}

/// Replay one profile against a fresh coordinator built from the
/// artifacts in `dir`: submit on the sampled arrival schedule, drain
/// every stream, fetch the span-ring analysis, shut down, and report.
pub fn run_profile(
    dir: &Path,
    profile: &WorkloadProfile,
    hw: HardwareProfile,
) -> Result<ProfileReport> {
    let serving = profile.serving_config();
    let engine_dir = dir.to_path_buf();
    let coord = Coordinator::new(
        move || harness::build_engine_with_serving(&engine_dir, &serving, hw),
        profile.seed,
    );

    let mut r = Rng::new(profile.seed);
    let gaps = profile.arrival_gaps_s(&mut r);
    let prompts = profile.prompts();
    let mut streams = Vec::with_capacity(prompts.len());
    for (prompt, gap) in prompts.into_iter().zip(gaps) {
        std::thread::sleep(Duration::from_secs_f64(gap));
        let mut req = Request::new(prompt);
        req.max_tokens = profile.max_tokens;
        req.chat = false;
        streams.push(coord.submit(req));
    }

    let mut report = ProfileReport {
        name: profile.name.clone(),
        requests: profile.requests,
        requests_ok: 0,
        requests_failed: 0,
        ttft_s: Vec::new(),
        tpot_s: Vec::new(),
        queue_s: Vec::new(),
        slo: profile.slo,
        faults_injected: 0,
        transfer_retries: 0,
        deadline_cancellations: 0,
        analysis: Json::Null,
    };
    for stream in &streams {
        let mut finished = false;
        for ev in collect_events_timeout(stream, Duration::from_secs(300)) {
            match ev {
                Event::Done { wall_s, queue_wait_s, ttft_s, new_tokens, .. } => {
                    report.requests_ok += 1;
                    report.ttft_s.push(queue_wait_s + ttft_s);
                    let decode_tokens = new_tokens.saturating_sub(1).max(1) as f64;
                    report.tpot_s.push((wall_s - ttft_s).max(0.0) / decode_tokens);
                    report.queue_s.push(queue_wait_s);
                    finished = true;
                }
                Event::Error { .. } | Event::Failed { .. } => {
                    report.requests_failed += 1;
                    finished = true;
                }
                Event::Token { .. } => {}
            }
        }
        if !finished {
            report.requests_failed += 1;
        }
    }

    // the analysis must be fetched before shutdown — it runs on the
    // worker thread against the live engine's span ring. Answering it
    // also refreshes the fault gauges, so the reads below see the final
    // tick's totals (the per-tick mirror alone lags one iteration);
    // deadline_cancellations is a plain counter, incremented by the
    // worker before it answers, so it needs no such refresh
    report.analysis = coord.analyze()?;
    report.faults_injected = coord.metrics.gauge("faults_injected");
    report.transfer_retries = coord.metrics.gauge("transfer_retries");
    report.deadline_cancellations = coord.metrics.counter("deadline_cancellations");
    coord.shutdown();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaps_are_deterministic_positive_and_bounded() {
        let p = bursty(false);
        let a = p.arrival_gaps_s(&mut Rng::new(p.seed));
        let b = p.arrival_gaps_s(&mut Rng::new(p.seed));
        assert_eq!(a.len(), p.requests);
        assert_eq!(a, b, "same seed must reproduce the schedule");
        assert!(a.iter().all(|&g| g.is_finite() && (0.0..=MAX_GAP_S).contains(&g)));
    }

    #[test]
    fn burst_phases_arrive_faster_on_average() {
        // with a strong burst factor and many samples, mean gap in the
        // burst phases must come out below the calm phases
        let p = WorkloadProfile {
            requests: 2000,
            burst_len: 10,
            burst_factor: 10.0,
            ..bursty(false)
        };
        let gaps = p.arrival_gaps_s(&mut Rng::new(1));
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        for (i, g) in gaps.iter().enumerate() {
            if (i / p.burst_len) % 2 == 0 {
                fast.push(*g);
            } else {
                slow.push(*g);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&fast) < mean(&slow), "burst phases must be denser");
    }

    #[test]
    fn prompts_fit_the_tiny_context_window() {
        // ByteTokenizer: 1 byte = 1 token; ModelConfig::tiny has 512
        // positions. Prompt + budget must always fit.
        for p in [bursty(false), chat(false), rag(false)] {
            let prompts = p.prompts();
            assert_eq!(prompts.len(), p.requests, "{}", p.name);
            for text in &prompts {
                assert!(
                    text.len() + p.max_tokens < 512,
                    "{}: prompt of {} bytes + {} budget overflows the window",
                    p.name,
                    text.len(),
                    p.max_tokens
                );
            }
        }
    }

    #[test]
    fn chat_turns_extend_their_transcript() {
        let p = chat(false);
        let (users, turns) = match p.prompt {
            PromptShape::Chat { users, turns } => (users, turns),
            _ => unreachable!(),
        };
        let prompts = p.prompts();
        // turn-major emission: request (t * users + u) is user u's turn t,
        // and each later turn starts with the previous one
        for u in 0..users {
            for t in 1..turns {
                let prev = &prompts[(t - 1) * users + u];
                let cur = &prompts[t * users + u];
                assert!(
                    cur.starts_with(prev.as_str()),
                    "user {u} turn {t} must extend turn {}",
                    t - 1
                );
            }
        }
    }

    #[test]
    fn rag_prompts_share_their_context() {
        let prompts = rag(false).prompts();
        let ctx_end = prompts[0].find("question:").expect("question marker");
        let ctx = &prompts[0][..ctx_end];
        assert!(ctx.len() > 100, "rag context should dominate the prompt");
        assert!(prompts.iter().all(|p| p.starts_with(ctx)));
    }

    #[test]
    fn chaos_profile_is_transient_only_and_validates() {
        let p = chaos(true);
        assert!(p.faults.enabled, "chaos must actually inject");
        // transient-only: nothing may escalate to degradation or a
        // fatal, or the bit-transparency contract breaks
        assert_eq!(p.faults.exhaust_p, 0.0);
        assert_eq!(p.faults.fatal_p, 0.0);
        assert_eq!(p.faults.fatal_at_gate, None);
        assert!(p.faults.transfer_fail_p > 0.0);
        let s = p.serving_config();
        assert!(s.faults.enabled, "the plan must reach the engine config");
        assert!(s.validate().is_ok());
        // the other profiles stay fault-free
        for clean in [bursty(true), chat(true), rag(true)] {
            assert!(!clean.serving_config().faults.enabled, "{}", clean.name);
        }
    }

    #[test]
    fn serving_config_always_traces_and_never_suffix_stops() {
        for p in [bursty(true), chat(true), rag(true), chaos(true)] {
            let s = p.serving_config();
            assert!(s.trace, "{}: analysis needs the span ring", p.name);
            assert!(s.stop_suffix.is_empty(), "{}: token counts must be budget-driven", p.name);
            assert_eq!(s.max_concurrent_sessions, p.width);
            assert_eq!(s.prefix_cache, p.prefix_cache);
            assert_eq!(s.chunked_prefill, p.chunked_prefill);
            assert!(s.validate().is_ok());
        }
    }

    #[test]
    fn report_row_schema_and_attainment() {
        let report = ProfileReport {
            name: "unit".into(),
            requests: 3,
            requests_ok: 2,
            requests_failed: 1,
            ttft_s: vec![0.1, 0.3],
            tpot_s: vec![0.01, 0.02],
            queue_s: vec![0.0, 0.2],
            slo: SloTargets {
                ttft_p50_s: 0.2,
                ttft_p99_s: 0.25,
                tpot_p50_s: 1.0,
                tpot_p99_s: 1.0,
            },
            faults_injected: 5,
            transfer_retries: 3,
            deadline_cancellations: 1,
            analysis: Json::obj(vec![
                ("attribution", Json::obj(vec![("compute", 1.0.into())])),
                ("whatif", Json::arr(vec![])),
            ]),
        };
        let row = report.to_json();
        assert_eq!(row.get("profile").and_then(Json::as_str), Some("unit"));
        assert_eq!(row.get("requests_ok").and_then(Json::as_usize), Some(2));
        assert_eq!(row.get("requests_failed").and_then(Json::as_usize), Some(1));
        assert_eq!(row.get("faults_injected").and_then(Json::as_usize), Some(5));
        assert_eq!(row.get("transfer_retries").and_then(Json::as_usize), Some(3));
        assert_eq!(row.get("deadline_cancellations").and_then(Json::as_usize), Some(1));
        // nearest-rank on [0.1, 0.3]: p50 = 0.1 <= 0.2 target, p99 = 0.3 > 0.25
        assert_eq!(row.get("ttft_p50_attained").and_then(Json::as_bool), Some(true));
        assert_eq!(row.get("ttft_p99_attained").and_then(Json::as_bool), Some(false));
        assert_eq!(row.get("tpot_p99_attained").and_then(Json::as_bool), Some(true));
        // percentiles are monotone in q by construction
        let p50 = row.get("ttft_p50_s").and_then(Json::as_f64).unwrap();
        let p99 = row.get("ttft_p99_s").and_then(Json::as_f64).unwrap();
        assert!(p50 <= p99);
        // the analysis rides along
        assert!(row.get("attribution").and_then(|a| a.get("compute")).is_some());
        assert!(row.get("whatif").and_then(Json::as_arr).is_some());
        // and the console line renders both attained and missed marks
        let line = report.summary();
        assert!(line.contains("2/3 ok") && line.contains("MISS") && line.contains("ok)"));
    }

    #[test]
    fn missing_analysis_degrades_to_null_fields() {
        let report = ProfileReport {
            name: "unit".into(),
            requests: 0,
            requests_ok: 0,
            requests_failed: 0,
            ttft_s: vec![],
            tpot_s: vec![],
            queue_s: vec![],
            slo: bursty(true).slo,
            faults_injected: 0,
            transfer_retries: 0,
            deadline_cancellations: 0,
            analysis: Json::Null,
        };
        let row = report.to_json();
        assert_eq!(row.get("attribution"), Some(&Json::Null));
        assert_eq!(row.get("whatif"), Some(&Json::Null));
    }
}
